"""NoC engine edge cases: empty flow sets and degenerate (1xN / Nx1) grids.

The vectorized ``analyze`` and the scalar ``analyze_reference`` must agree
on the corners the planner rarely exercises: zero flows, all-dropped flows
(zero words / self loops), single-row and single-column substrates (where
torus wrap, AMP express links and flattened-butterfly row hops all
degenerate), and the 1x1 grid with no links at all.

The batched engine (``analyze_batch`` over shared ``RouteIncidence``
tables) is pinned against both: singleton batches must equal ``analyze``
bit for bit, whole frontiers must match the scalar oracle's link loads,
and the vectorized multi-set table builder must reproduce the per-set
builder exactly.
"""
import dataclasses as dc

import numpy as np
import pytest

from repro.core import PAPER_HW
from repro.core.noc import (Flow, FlowBatch, Topology, analyze,
                            analyze_batch, analyze_reference,
                            route_incidence, topology_link_count,
                            _build_incidence, _build_incidence_batch)

ALL_TOPOLOGIES = list(Topology)

ROW_HW = dc.replace(PAPER_HW, pe_rows=1, pe_cols=16)    # 1xN
COL_HW = dc.replace(PAPER_HW, pe_rows=16, pe_cols=1)    # Nx1
DOT_HW = dc.replace(PAPER_HW, pe_rows=1, pe_cols=1)     # single PE


def _assert_stats_equal(a, b):
    assert a.worst_channel_load == b.worst_channel_load
    assert a.max_path_hops == b.max_path_hops
    assert a.num_links_used == b.num_links_used
    assert a.link_count == b.link_count
    np.testing.assert_allclose(a.total_hop_words, b.total_hop_words,
                               rtol=1e-12)
    np.testing.assert_allclose(a.total_wire_words, b.total_wire_words,
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# zero-flow corners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("hw", [PAPER_HW, ROW_HW, COL_HW, DOT_HW],
                         ids=["32x32", "1x16", "16x1", "1x1"])
def test_empty_flow_batch_matches_reference(topology, hw):
    st = analyze(FlowBatch.empty(), hw, topology)
    ref = analyze_reference([], hw, topology)
    _assert_stats_equal(st, ref)
    assert st.worst_channel_load == 0.0
    assert st.num_links_used == 0
    assert st.max_path_hops == 0
    # an empty interval is never congested and costs no hop energy
    assert not st.congested(1.0)
    assert st.interval_comm_delay(7.0) == 7.0
    assert st.hop_energy(hw) == 0.0


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_all_dropped_flows_match_reference(topology):
    """Zero-word flows and self-loops are dropped by both engines."""
    flows = [Flow((0, 0), (3, 4), 0.0),       # zero words
             Flow((2, 2), (2, 2), 5.0),       # self loop
             Flow((1, 1), (1, 1), 0.0)]
    st = analyze(flows, PAPER_HW, topology)
    ref = analyze_reference(flows, PAPER_HW, topology)
    _assert_stats_equal(st, ref)
    assert st.worst_channel_load == 0.0
    assert st.total_hop_words == 0.0


# ---------------------------------------------------------------------------
# degenerate grids
# ---------------------------------------------------------------------------


def _random_flows(rng, n, rows, cols):
    src_r = rng.integers(0, rows, n)
    src_c = rng.integers(0, cols, n)
    dst_r = rng.integers(0, rows, n)
    dst_c = rng.integers(0, cols, n)
    words = rng.uniform(0.0, 5.0, n)
    words[rng.random(n) < 0.1] = 0.0
    return [Flow((int(a), int(b)), (int(c), int(d)), float(w))
            for a, b, c, d, w in zip(src_r, src_c, dst_r, dst_c, words)]


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("hw", [ROW_HW, COL_HW], ids=["1x16", "16x1"])
def test_skinny_grids_match_reference(topology, hw):
    rng = np.random.default_rng(7)
    for n in (1, 2, 33, 400):
        flows = _random_flows(rng, n, hw.pe_rows, hw.pe_cols)
        _assert_stats_equal(analyze(flows, hw, topology),
                            analyze_reference(flows, hw, topology))


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_single_pe_grid_has_no_traffic(topology):
    """On a 1x1 substrate every flow is a self-loop."""
    flows = [Flow((0, 0), (0, 0), 9.0)]
    st = analyze(flows, DOT_HW, topology)
    _assert_stats_equal(st, analyze_reference(flows, DOT_HW, topology))
    assert st.worst_channel_load == 0.0


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_skinny_grid_end_to_end_flow(topology):
    """A full-span flow on a 1xN row: hop counts follow the topology
    (express links shorten AMP, wrap shortens nothing on a full span,
    flattened butterfly is a single row hop)."""
    hw = ROW_HW
    flows = [Flow((0, 0), (0, hw.pe_cols - 1), 2.0)]
    st = analyze(flows, hw, topology)
    _assert_stats_equal(st, analyze_reference(flows, hw, topology))
    assert st.total_hop_words == 2.0 * st.max_path_hops
    if topology == Topology.FLATTENED_BUTTERFLY:
        assert st.max_path_hops == 1
    elif topology == Topology.TORUS:
        assert st.max_path_hops == 1          # wrap link closes the ring
    elif topology == Topology.AMP:
        assert st.max_path_hops < hw.pe_cols - 1
    else:
        assert st.max_path_hops == hw.pe_cols - 1


# ---------------------------------------------------------------------------
# batched engine: analyze_batch / RouteIncidence
# ---------------------------------------------------------------------------


def _assert_stats_identical(a, b):
    """Bit-level equality — the analyze_batch vs analyze contract."""
    assert a.worst_channel_load == b.worst_channel_load
    assert a.total_hop_words == b.total_hop_words
    assert a.total_wire_words == b.total_wire_words
    assert a.max_path_hops == b.max_path_hops
    assert a.num_links_used == b.num_links_used
    assert a.link_count == b.link_count


def _random_batch(rng, n, rows, cols, zero_frac=0.0):
    src = np.stack([rng.integers(0, rows, n),
                    rng.integers(0, cols, n)], axis=1).astype(np.int64)
    dst = np.stack([rng.integers(0, rows, n),
                    rng.integers(0, cols, n)], axis=1).astype(np.int64)
    words = rng.uniform(0.1, 9.0, n)
    if zero_frac:
        words[rng.random(n) < zero_frac] = 0.0
    return FlowBatch(src, dst, words)


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("hw", [PAPER_HW, ROW_HW, COL_HW, DOT_HW],
                         ids=["32x32", "1x16", "16x1", "1x1"])
def test_analyze_batch_singleton_equals_analyze(topology, hw):
    """``analyze_batch([fb]) == analyze(fb)`` bit for bit over random
    placements, including zero-word flows (which force the analyze
    fallback) and empty batches."""
    rng = np.random.default_rng(11)
    fbs = [FlowBatch.empty()]
    for n in (1, 2, 17, 256):
        fbs.append(_random_batch(rng, n, hw.pe_rows, hw.pe_cols))
        fbs.append(_random_batch(rng, n, hw.pe_rows, hw.pe_cols,
                                 zero_frac=0.2))
    for fb in fbs:
        _assert_stats_identical(analyze_batch([fb], hw, topology)[0],
                                analyze(fb, hw, topology))


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_analyze_batch_frontier_matches_reference_loads(topology):
    """A whole frontier in one call matches the scalar oracle: link loads
    and hop counts bit-exact, float totals to summation-order tolerance
    (the pre-existing analyze vs analyze_reference contract)."""
    rng = np.random.default_rng(23)
    fbs = [_random_batch(rng, n, PAPER_HW.pe_rows, PAPER_HW.pe_cols)
           for n in (3, 40, 7, 129, 1, 64)]
    for st, fb in zip(analyze_batch(fbs, PAPER_HW, topology), fbs):
        ref = analyze_reference(
            [Flow(tuple(s), tuple(d), float(w))
             for s, d, w in zip(fb.src, fb.dst, fb.words)],
            PAPER_HW, topology)
        assert st.worst_channel_load == ref.worst_channel_load
        assert st.max_path_hops == ref.max_path_hops
        assert st.num_links_used == ref.num_links_used
        assert st.link_count == ref.link_count
        np.testing.assert_allclose(st.total_hop_words, ref.total_hop_words,
                                   rtol=1e-12)
        np.testing.assert_allclose(st.total_wire_words,
                                   ref.total_wire_words, rtol=1e-12)


def test_torus_wraparound_routes():
    """Full-span torus flows take the wrap link — one hop, and the
    incidence table prices the wrap exactly like ``analyze``."""
    hw = ROW_HW
    fb = FlowBatch(np.array([[0, 0]], np.int64),
                   np.array([[0, hw.pe_cols - 1]], np.int64),
                   np.array([3.0]))
    st = analyze_batch([fb], hw, Topology.TORUS)[0]
    _assert_stats_identical(st, analyze(fb, hw, Topology.TORUS))
    assert st.max_path_hops == 1            # ring closes the span
    assert st.worst_channel_load == 3.0
    inc = route_incidence(fb, hw, Topology.TORUS)
    # the wrap hop is the flow's last, so it lands on the consumer's
    # first adaptive ingress port rather than a wire link
    assert inc.link_keys() == [((0, hw.pe_cols - 1), "in", 0)]


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("rows,cols", [(8, 8), (1, 16), (16, 1), (5, 3)],
                         ids=["8x8", "1x16", "16x1", "5x3"])
def test_build_incidence_batch_bit_parity(topology, rows, cols):
    """The multi-set table builder reproduces the per-set builder exactly
    (every array field, including the per-set sorted link tables)."""
    rng = np.random.default_rng(3)
    express = PAPER_HW.amp_link_len if topology == Topology.AMP else 1
    sets = []
    for _ in range(17):
        n = int(rng.integers(0, 33))
        sets.append((
            np.stack([rng.integers(0, rows, n),
                      rng.integers(0, cols, n)], 1).astype(np.int64),
            np.stack([rng.integers(0, rows, n),
                      rng.integers(0, cols, n)], 1).astype(np.int64)))
    batch = _build_incidence_batch(sets, rows, cols, topology, express)
    for (src, dst), got in zip(sets, batch):
        want = _build_incidence(src, dst, rows, cols, topology, express)
        for f in ("keep", "path_len", "fidx", "inv", "wire", "uniq"):
            assert np.array_equal(getattr(want, f), getattr(got, f)), f
        assert want.max_path_hops == got.max_path_hops
        assert want.link_count == got.link_count


@pytest.mark.parametrize("hw", [ROW_HW, COL_HW], ids=["1x16", "16x1"])
def test_skinny_link_counts_are_consistent(hw):
    """Link budgets on degenerate grids stay ordered mesh <= amp and the
    1-D flattened butterfly is the all-to-all row/column clique."""
    n = max(hw.pe_rows, hw.pe_cols)
    mesh = topology_link_count(hw.pe_rows, hw.pe_cols, Topology.MESH, 1)
    amp = topology_link_count(hw.pe_rows, hw.pe_cols, Topology.AMP,
                              hw.amp_link_len)
    fb = topology_link_count(hw.pe_rows, hw.pe_cols,
                             Topology.FLATTENED_BUTTERFLY, 1)
    assert mesh == n - 1
    assert mesh <= amp < 2 * mesh + n
    assert fb == n * (n - 1) // 2
