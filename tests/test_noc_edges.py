"""NoC engine edge cases: empty flow sets and degenerate (1xN / Nx1) grids.

The vectorized ``analyze`` and the scalar ``analyze_reference`` must agree
on the corners the planner rarely exercises: zero flows, all-dropped flows
(zero words / self loops), single-row and single-column substrates (where
torus wrap, AMP express links and flattened-butterfly row hops all
degenerate), and the 1x1 grid with no links at all.
"""
import dataclasses as dc

import numpy as np
import pytest

from repro.core import PAPER_HW
from repro.core.noc import (Flow, FlowBatch, Topology, analyze,
                            analyze_reference, topology_link_count)

ALL_TOPOLOGIES = list(Topology)

ROW_HW = dc.replace(PAPER_HW, pe_rows=1, pe_cols=16)    # 1xN
COL_HW = dc.replace(PAPER_HW, pe_rows=16, pe_cols=1)    # Nx1
DOT_HW = dc.replace(PAPER_HW, pe_rows=1, pe_cols=1)     # single PE


def _assert_stats_equal(a, b):
    assert a.worst_channel_load == b.worst_channel_load
    assert a.max_path_hops == b.max_path_hops
    assert a.num_links_used == b.num_links_used
    assert a.link_count == b.link_count
    np.testing.assert_allclose(a.total_hop_words, b.total_hop_words,
                               rtol=1e-12)
    np.testing.assert_allclose(a.total_wire_words, b.total_wire_words,
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# zero-flow corners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("hw", [PAPER_HW, ROW_HW, COL_HW, DOT_HW],
                         ids=["32x32", "1x16", "16x1", "1x1"])
def test_empty_flow_batch_matches_reference(topology, hw):
    st = analyze(FlowBatch.empty(), hw, topology)
    ref = analyze_reference([], hw, topology)
    _assert_stats_equal(st, ref)
    assert st.worst_channel_load == 0.0
    assert st.num_links_used == 0
    assert st.max_path_hops == 0
    # an empty interval is never congested and costs no hop energy
    assert not st.congested(1.0)
    assert st.interval_comm_delay(7.0) == 7.0
    assert st.hop_energy(hw) == 0.0


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_all_dropped_flows_match_reference(topology):
    """Zero-word flows and self-loops are dropped by both engines."""
    flows = [Flow((0, 0), (3, 4), 0.0),       # zero words
             Flow((2, 2), (2, 2), 5.0),       # self loop
             Flow((1, 1), (1, 1), 0.0)]
    st = analyze(flows, PAPER_HW, topology)
    ref = analyze_reference(flows, PAPER_HW, topology)
    _assert_stats_equal(st, ref)
    assert st.worst_channel_load == 0.0
    assert st.total_hop_words == 0.0


# ---------------------------------------------------------------------------
# degenerate grids
# ---------------------------------------------------------------------------


def _random_flows(rng, n, rows, cols):
    src_r = rng.integers(0, rows, n)
    src_c = rng.integers(0, cols, n)
    dst_r = rng.integers(0, rows, n)
    dst_c = rng.integers(0, cols, n)
    words = rng.uniform(0.0, 5.0, n)
    words[rng.random(n) < 0.1] = 0.0
    return [Flow((int(a), int(b)), (int(c), int(d)), float(w))
            for a, b, c, d, w in zip(src_r, src_c, dst_r, dst_c, words)]


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
@pytest.mark.parametrize("hw", [ROW_HW, COL_HW], ids=["1x16", "16x1"])
def test_skinny_grids_match_reference(topology, hw):
    rng = np.random.default_rng(7)
    for n in (1, 2, 33, 400):
        flows = _random_flows(rng, n, hw.pe_rows, hw.pe_cols)
        _assert_stats_equal(analyze(flows, hw, topology),
                            analyze_reference(flows, hw, topology))


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_single_pe_grid_has_no_traffic(topology):
    """On a 1x1 substrate every flow is a self-loop."""
    flows = [Flow((0, 0), (0, 0), 9.0)]
    st = analyze(flows, DOT_HW, topology)
    _assert_stats_equal(st, analyze_reference(flows, DOT_HW, topology))
    assert st.worst_channel_load == 0.0


@pytest.mark.parametrize("topology", ALL_TOPOLOGIES)
def test_skinny_grid_end_to_end_flow(topology):
    """A full-span flow on a 1xN row: hop counts follow the topology
    (express links shorten AMP, wrap shortens nothing on a full span,
    flattened butterfly is a single row hop)."""
    hw = ROW_HW
    flows = [Flow((0, 0), (0, hw.pe_cols - 1), 2.0)]
    st = analyze(flows, hw, topology)
    _assert_stats_equal(st, analyze_reference(flows, hw, topology))
    assert st.total_hop_words == 2.0 * st.max_path_hops
    if topology == Topology.FLATTENED_BUTTERFLY:
        assert st.max_path_hops == 1
    elif topology == Topology.TORUS:
        assert st.max_path_hops == 1          # wrap link closes the ring
    elif topology == Topology.AMP:
        assert st.max_path_hops < hw.pe_cols - 1
    else:
        assert st.max_path_hops == hw.pe_cols - 1


@pytest.mark.parametrize("hw", [ROW_HW, COL_HW], ids=["1x16", "16x1"])
def test_skinny_link_counts_are_consistent(hw):
    """Link budgets on degenerate grids stay ordered mesh <= amp and the
    1-D flattened butterfly is the all-to-all row/column clique."""
    n = max(hw.pe_rows, hw.pe_cols)
    mesh = topology_link_count(hw.pe_rows, hw.pe_cols, Topology.MESH, 1)
    amp = topology_link_count(hw.pe_rows, hw.pe_cols, Topology.AMP,
                              hw.amp_link_len)
    fb = topology_link_count(hw.pe_rows, hw.pe_cols,
                             Topology.FLATTENED_BUTTERFLY, 1)
    assert mesh == n - 1
    assert mesh <= amp < 2 * mesh + n
    assert fb == n * (n - 1) // 2
