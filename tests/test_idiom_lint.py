"""The AST idiom lint (tools/idiom_lint.py): the repo passes clean, and
each rule actually fires on a seeded violation."""
import importlib.util
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def idiom_lint():
    path = REPO_ROOT / "tools" / "idiom_lint.py"
    spec = importlib.util.spec_from_file_location("idiom_lint", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["idiom_lint"] = mod
    spec.loader.exec_module(mod)
    return mod


def _mini_repo(tmp_path, core_files, test_source=""):
    """Lay out the directory shape run() expects."""
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True)
    # keep the curated twin modules present and legal by default
    defaults = {
        "noc.py": "def analyze():\n    pass\n\n"
                  "def analyze_reference():\n    pass\n",
        "simulator.py": "def simulate_plan():\n    pass\n\n"
                        "def simulate_reference():\n    pass\n",
        "planner.py": "def plan_x():\n    pass\n\n"
                      "def plan_x_reference():\n    pass\n",
    }
    defaults.update(core_files)
    for name, src in defaults.items():
        (core / name).write_text(textwrap.dedent(src))
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_x.py").write_text(textwrap.dedent(test_source))
    return tmp_path


def test_repo_is_idiom_clean(idiom_lint):
    problems = idiom_lint.run(REPO_ROOT)
    assert problems == [], "\n".join(problems)


def test_untested_strategy_fires_i001(idiom_lint, tmp_path):
    root = _mini_repo(
        tmp_path,
        {"planner.py": """
            def plan_x():
                pass

            def plan_x_reference():
                pass

            register_strategy("ghost-strategy", plan_x, None)
            register_strategy("covered", plan_x, None)
        """},
        test_source='NAME = "covered"\n')
    problems = idiom_lint.run(root)
    assert any("I001" in p and "ghost-strategy" in p for p in problems)
    assert not any("covered" in p for p in problems)


def test_missing_reference_twin_fires_i002(idiom_lint, tmp_path):
    root = _mini_repo(tmp_path, {"noc.py": "def analyze():\n    pass\n"})
    problems = idiom_lint.run(root)
    assert any("I002" in p and "noc.py" in p for p in problems)


def test_orphan_reference_fires_i002(idiom_lint, tmp_path):
    root = _mini_repo(tmp_path, {
        "noc.py": "def analyze_reference():\n    pass\n"})
    problems = idiom_lint.run(root)
    assert any("I002" in p and "analyze_reference" in p for p in problems)


def test_prefix_family_twin_satisfies_i002(idiom_lint, tmp_path):
    # simulate_reference twins simulate_plan/simulate_segment (prefix
    # family) — the repo's actual simulator.py shape
    root = _mini_repo(tmp_path, {
        "simulator.py": "def simulate_segment():\n    pass\n\n"
                        "def simulate_reference():\n    pass\n"})
    assert not any("simulator" in p for p in idiom_lint.run(root))


def test_unseeded_np_random_fires_i003(idiom_lint, tmp_path):
    root = _mini_repo(tmp_path, {"extra.py": """
        import numpy as np

        def noisy():
            return np.random.rand(3)

        def seeded():
            return np.random.default_rng(0).random(3)

        def unseeded_ctor():
            return np.random.default_rng()
    """})
    problems = [p for p in idiom_lint.run(root) if "I003" in p]
    assert len(problems) == 2, problems
    assert any("np.random.rand" in p for p in problems)
    assert any("without an explicit seed" in p for p in problems)


def test_cli_exit_codes(idiom_lint, tmp_path, capsys):
    assert idiom_lint.main(["--root", str(REPO_ROOT)]) == 0
    root = _mini_repo(tmp_path, {"noc.py": "def analyze():\n    pass\n"})
    assert idiom_lint.main(["--root", str(root)]) == 1
    assert "I002" in capsys.readouterr().out
