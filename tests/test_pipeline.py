"""Pod-level PipeOrgan placement: properties + cost-model behaviour."""
import numpy as np
import pytest

from repro.distributed.pipeline import (StageOrg, choose_placement,
                                        handoff_permutation, hop_distance,
                                        placement_cost, stage_of_device)


@pytest.mark.parametrize("org", list(StageOrg))
@pytest.mark.parametrize("n_stages,n_dev", [(2, 16), (4, 16), (8, 16),
                                            (4, 64), (16, 16)])
def test_stage_cover(org, n_stages, n_dev):
    stages = stage_of_device(org, n_stages, n_dev)
    assert len(stages) == n_dev
    counts = np.bincount(stages, minlength=n_stages)
    assert counts.sum() == n_dev
    assert (counts == n_dev // n_stages).all()


@pytest.mark.parametrize("org", list(StageOrg))
def test_permutation_is_valid(org):
    perm = handoff_permutation(org, 4, 16)
    srcs = [s for s, _ in perm]
    assert sorted(srcs) == list(range(16))     # every device sends once


def test_striped_is_one_hop():
    """Fig. 10 at pod scale: striping makes every handoff a neighbour."""
    perm = handoff_permutation(StageOrg.STRIPED, 4, 16)
    non_wrap = [(s, d) for s, d in perm
                if hop_distance(s, d, 16, torus=True) > 1]
    assert not non_wrap


def test_blocked_pays_block_distance():
    perm = handoff_permutation(StageOrg.BLOCKED, 4, 16)
    dists = [hop_distance(s, d, 16, torus=True) for s, d in perm]
    assert max(dists) >= 4     # crosses a 4-device block


def test_striped_beats_blocked_on_handoff():
    b = placement_cost(StageOrg.BLOCKED, 4, 16, 1e9)
    s = placement_cost(StageOrg.STRIPED, 4, 16, 1e9)
    assert s["worst_link_bytes"] < b["worst_link_bytes"]
    assert s["max_hops"] <= b["max_hops"]


def test_torus_wrap_rescues_blocked():
    """AMP analogue: wrap-around links cut blocked's loop-back cost."""
    ring = placement_cost(StageOrg.BLOCKED, 4, 16, 1e9, torus=True)
    line = placement_cost(StageOrg.BLOCKED, 4, 16, 1e9, torus=False)
    assert ring["max_hops"] < line["max_hops"]


def test_choose_placement_tradeoff():
    # pipelining-dominated traffic -> striped
    assert choose_placement(4, 16, bytes_per_handoff=1e9,
                            tp_bytes_per_stage=1e6) == StageOrg.STRIPED
    # TP-dominated -> blocked (keep collectives local)
    assert choose_placement(4, 16, bytes_per_handoff=1e6,
                            tp_bytes_per_stage=1e9) == StageOrg.BLOCKED
