#!/usr/bin/env python
"""AST-based idiom lint for the planner codebase (pure stdlib).

Three repo-specific rules that generic linters cannot express:

  I001  every strategy name registered via ``register_strategy("<name>",
        ...)`` in ``src/repro`` must appear as a string literal somewhere
        under ``tests/`` — a registered strategy nobody's parity/golden
        tests exercise is dead weight or, worse, silently broken.
  I002  the curated vectorized modules (``noc.py``, ``simulator.py``,
        ``planner.py``) must keep their ``*_reference`` twins: each must
        define at least one top-level ``<base>_reference`` function, and
        every ``<base>_reference`` must sit next to a top-level
        ``<base>`` — the differential-testing contract (vectorized fast
        path vs. readable oracle) that the parity suites rely on.
  I003  no unseeded ``np.random`` in ``src/repro/core``: the planner and
        analysis layer must be deterministic, so only explicitly seeded
        constructors (``np.random.default_rng(seed)`` /
        ``np.random.RandomState(seed)``) are allowed; the legacy global
        state (``np.random.rand`` etc., or a zero-argument constructor)
        is flagged.

Usage:  python tools/idiom_lint.py [--root REPO_ROOT]
Exit status 1 when any rule fires.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Dict, List, Set, Tuple

#: modules under src/repro/core that pair a vectorized implementation
#: with a scalar ``*_reference`` oracle (rule I002).
REFERENCE_TWIN_MODULES = ("noc.py", "simulator.py", "planner.py")

#: seeded-constructor allowlist for rule I003; each still needs >= 1
#: positional argument (the seed).
SEEDED_CTORS = {"default_rng", "RandomState"}


def _parse(path: Path) -> ast.AST:
    return ast.parse(path.read_text(), filename=str(path))


def _iter_py(root: Path) -> List[Path]:
    return sorted(p for p in root.rglob("*.py") if p.is_file())


# -- I001 -------------------------------------------------------------------


def registered_strategy_names(src_root: Path) -> Dict[str, Path]:
    """Strategy-name literal -> file registering it."""
    out: Dict[str, Path] = {}
    for path in _iter_py(src_root):
        for node in ast.walk(_parse(path)):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None)
            if name != "register_strategy" or not node.args:
                continue
            first = node.args[0]
            if isinstance(first, ast.Constant) and isinstance(first.value,
                                                              str):
                out.setdefault(first.value, path)
    return out


def test_string_literals(tests_root: Path) -> Set[str]:
    out: Set[str] = set()
    for path in _iter_py(tests_root):
        for node in ast.walk(_parse(path)):
            if isinstance(node, ast.Constant) and isinstance(node.value,
                                                             str):
                out.add(node.value)
    return out


def check_strategies_tested(src_root: Path,
                            tests_root: Path) -> List[str]:
    tested = test_string_literals(tests_root)
    problems = []
    for name, path in sorted(registered_strategy_names(src_root).items()):
        if name not in tested:
            problems.append(
                f"I001 {path}: strategy {name!r} is registered but never "
                f"named by any test under {tests_root}")
    return problems


# -- I002 -------------------------------------------------------------------


def check_reference_twins(core_root: Path) -> List[str]:
    problems = []
    for mod in REFERENCE_TWIN_MODULES:
        path = core_root / mod
        if not path.exists():
            problems.append(f"I002 {path}: curated module missing")
            continue
        top = [n.name for n in _parse(path).body  # type: ignore[attr-defined]
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        refs = [n for n in top if n.endswith("_reference")]
        if not refs:
            problems.append(
                f"I002 {path}: no top-level *_reference oracle — the "
                "vectorized/reference twin contract is broken")
        for ref in refs:
            base = ref[:-len("_reference")]
            # exact twin (analyze/analyze_reference) or prefix family
            # (simulate_reference oracles simulate_plan/simulate_segment)
            if base not in top and not any(
                    n.startswith(base + "_") and not n.endswith("_reference")
                    for n in top):
                problems.append(
                    f"I002 {path}: {ref}() has no top-level {base}() or "
                    f"{base}_*() twin")
    return problems


# -- I003 -------------------------------------------------------------------


def _np_random_attr(node: ast.AST) -> str:
    """'' unless node is an ``np.random.<X>`` / ``numpy.random.<X>``
    attribute chain; then X."""
    if not isinstance(node, ast.Attribute):
        return ""
    mid = node.value
    if (isinstance(mid, ast.Attribute) and mid.attr == "random"
            and isinstance(mid.value, ast.Name)
            and mid.value.id in ("np", "numpy")):
        return node.attr
    return ""


def check_seeded_random(core_root: Path) -> List[str]:
    problems = []
    for path in _iter_py(core_root):
        tree = _parse(path)
        calls = {id(n.func): n for n in ast.walk(tree)
                 if isinstance(n, ast.Call)}
        for node in ast.walk(tree):
            attr = _np_random_attr(node)
            if not attr:
                continue
            call = calls.get(id(node))
            line = getattr(node, "lineno", 0)
            if attr in SEEDED_CTORS:
                if call is not None and (call.args or call.keywords):
                    continue        # explicitly seeded constructor: fine
                problems.append(
                    f"I003 {path}:{line}: np.random.{attr}() without an "
                    "explicit seed")
            else:
                problems.append(
                    f"I003 {path}:{line}: np.random.{attr} uses the global "
                    "unseeded RNG state; use np.random.default_rng(seed)")
    return problems


# -- driver -----------------------------------------------------------------


def run(root: Path) -> List[str]:
    src_root = root / "src" / "repro"
    core_root = src_root / "core"
    tests_root = root / "tests"
    problems: List[str] = []
    problems += check_strategies_tested(src_root, tests_root)
    problems += check_reference_twins(core_root)
    problems += check_seeded_random(core_root)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path,
                    default=Path(__file__).resolve().parents[1],
                    help="repository root (default: this repo)")
    args = ap.parse_args(argv)
    problems = run(args.root)
    for p in problems:
        print(p)
    n = len(problems)
    print(f"idiom_lint: {n} problem{'s' if n != 1 else ''}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
