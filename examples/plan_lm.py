"""Plan LM decode steps with periodicity folding and a span shelf.

Lowers two structurally different serving workloads to planner graphs —
a routed-MoE decode step (granite-moe: router + top-k expert branches
per layer) and a recurrent-hybrid decode step (recurrentgemma: RG-LRU
scans cycling with local attention) — and plans them three ways:

  1. cold, unfolded    — every stage-1 segment solved independently
  2. cold, folded      — one solve per structural equivalence class,
                         the rest tiled by translation (bit-identical)
  3. shelf-warm replan — memory tier dropped, spans served from the
                         on-disk SpanShelf: zero DP segment solves

    PYTHONPATH=src python examples/plan_lm.py
"""
import tempfile
import time

from repro.configs import get_config
from repro.configs.lm_graphs import decode_graph
from repro.core import (PAPER_HW, SpanShelf, Topology, flow_batch_cache_clear,
                        periodic_regions, plan_diffs, set_span_shelf,
                        span_cache_clear)
from repro.core import noc, planner
from repro.core.planner import plan_pipeorgan


def cold() -> None:
    """Drop every cross-call planner cache (the shelf, if any, stays)."""
    planner._pair_traffic.cache_clear()
    planner._cached_place.cache_clear()
    planner._SPAN_SIG_CACHE.clear()
    planner._FOLD_SIG_CACHE.clear()
    span_cache_clear()
    flow_batch_cache_clear()
    noc.route_incidence_cache_clear()


with tempfile.TemporaryDirectory() as shelf_dir:
    for arch in ("granite-moe-1b-a400m", "recurrentgemma-2b"):
        cfg = get_config(arch)
        g = decode_graph(cfg)
        runs = periodic_regions(g)
        print(f"{g.name}: {len(g.ops)} ops, {cfg.n_layers} layers; "
              f"periodic runs "
              f"{[(r.start, r.period, r.count) for r in runs[:3]]}"
              f"{' ...' if len(runs) > 3 else ''}")

        cold()
        t0 = time.perf_counter()
        unfolded = plan_pipeorgan(g, PAPER_HW, Topology.AMP, fold=False)
        t_unfold = time.perf_counter() - t0

        cold()
        t0 = time.perf_counter()
        folded = plan_pipeorgan(g, PAPER_HW, Topology.AMP)
        t_fold = time.perf_counter() - t0
        assert plan_diffs(folded, unfolded) == [], "fold must be exact"

        # persist the solved spans, then replan as a "new process":
        # memory tier cleared, shelf intact
        shelf = SpanShelf(shelf_dir)
        set_span_shelf(shelf)
        cold()
        plan_pipeorgan(g, PAPER_HW, Topology.AMP)
        cold()
        t0 = time.perf_counter()
        warm = plan_pipeorgan(g, PAPER_HW, Topology.AMP)
        t_warm = time.perf_counter() - t0
        assert plan_diffs(folded, warm) == []
        set_span_shelf(None)

        print(f"  cold unfolded {t_unfold * 1e3:8.1f} ms")
        print(f"  cold folded   {t_fold * 1e3:8.1f} ms   "
              f"({t_unfold / t_fold:.1f}x, bit-identical)")
        print(f"  shelf-warm    {t_warm * 1e3:8.1f} ms   "
              f"(shelf: {shelf.hits} hits, {len(shelf)} spans on disk)")
        print(f"  plan: {len(folded.segments)} segments, "
              f"latency {folded.latency_cycles:.3e} cycles, "
              f"DRAM {folded.dram_bytes:.3e} B\n")

print("folding plans one representative per repeated layer structure and "
      "tiles the rest;\nthe shelf carries solved spans across processes "
      "(docs/planner.md).")
