"""End-to-end driver: train a small LM with the full production stack
(sharded step, grad accumulation, checkpoints, fault tolerance).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable's "~100M-model for a few hundred
steps"; the default preset is small enough to finish on a laptop CPU.
"""
import argparse

from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, train

PRESETS = {
    "tiny": ModelConfig(name="tiny-12m", arch_kind="dense", n_layers=4,
                        d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
                        vocab=4096, head_dim=64),
    "100m": ModelConfig(name="lm-100m", arch_kind="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab=32768, head_dim=64),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"training {cfg.name}: ~{cfg.n_params()/1e6:.0f}M params")
    data = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab)
    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    loop = TrainLoopConfig(steps=args.steps, ckpt_every=50,
                           ckpt_dir=args.ckpt_dir, log_every=10,
                           microbatches=args.microbatches)
    out = train(cfg, opt, loop, make_host_mesh, data,
                on_metrics=lambda s, m: print(
                    f"  step {s:4d}  loss {m['loss']:.4f}  "
                    f"gnorm {m['grad_norm']:.3f}"))
    print(f"done: final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
