"""Serve a small model with batched greedy decoding + int8 KV cache,
pricing the decode step from a persisted PipeOrgan plan artifact.

The offline-plan -> online-serve path: the first run ("warm-up") plans
the model's decode graph once and files the plan as a ``PlanArtifact``
in a ``PlanStore`` directory; every later run admits the artifact with
ZERO planner invocations — asserted below via the facade's cache
counters — which is how a serving fleet starts hot without paying the
planner at boot.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32 --kv-quant \
        [--plan-store DIR]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PAPER_HW, PlanRequest, PlanStore, Topology, get_planner
from repro.models import init_cache, init_model
from repro.runtime.serve_loop import decode_graph
from repro.runtime.steps import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--plan-store", default=".pipeorgan_plans",
                    help="directory of serialized plan artifacts")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config on CPU
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    # -- accelerator plan: artifact first, planner only on a cold store ----
    planner = get_planner()
    store = PlanStore(args.plan_store)
    request = PlanRequest(decode_graph(cfg), hw=PAPER_HW,
                          topology=Topology.AMP)
    plan = store.load(request)
    if plan is None:                       # warm-up: plan once, persist
        plan = planner.plan(request)
        path = store.save(request, plan)
        print(f"warm-up: planned and saved artifact -> {path}")
    misses_before = planner.cache_info().misses
    served = store.load(request)           # the serving path
    assert served is not None
    assert planner.cache_info().misses == misses_before, \
        "serving made a planner invocation despite a warm store"
    print(f"decode plan from store ({store.info()[0]} store hits, "
          f"0 planner invocations): {served.latency_cycles:.3e} cycles"
          f"/token, {served.dram_bytes:.3e} DRAM B/token")

    params = init_model(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_serve_step(cfg))

    B, T = args.batch, args.tokens + 8
    cache = init_cache(cfg, B, T)
    toks = jnp.ones((B, 1), jnp.int32)
    generated = [toks]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        toks, cache = step(params, toks, cache, jnp.int32(i))
        generated.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} kv_quant={cfg.kv_quant}")
    print(f"generated {args.tokens} tokens x batch {B} in {dt*1e3:.1f} ms "
          f"({args.tokens*B/dt:.0f} tok/s on CPU smoke config)")
    print("sample:", seq[0].tolist())


if __name__ == "__main__":
    main()
