"""Serve a small model with batched greedy decoding + int8 KV cache.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32 --kv-quant
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_cache, init_model
from repro.runtime.steps import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config on CPU
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_serve_step(cfg))

    B, T = args.batch, args.tokens + 8
    cache = init_cache(cfg, B, T)
    toks = jnp.ones((B, 1), jnp.int32)
    generated = [toks]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        toks, cache = step(params, toks, cache, jnp.int32(i))
        generated.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    seq = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} kv_quant={cfg.kv_quant}")
    print(f"generated {args.tokens} tokens x batch {B} in {dt*1e3:.1f} ms "
          f"({args.tokens*B/dt:.0f} tok/s on CPU smoke config)")
    print("sample:", seq[0].tolist())


if __name__ == "__main__":
    main()
