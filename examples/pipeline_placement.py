"""PipeOrgan spatial organization at pod scale: blocked vs striped
pipeline-stage placement on the ICI mesh.

    PYTHONPATH=src python examples/pipeline_placement.py
"""
from repro.distributed.pipeline import (StageOrg, choose_placement,
                                        handoff_permutation, placement_cost)

N_DEV = 16          # model-axis devices of one pod row
BYTES = 64 * 2048 * 2   # one microbatch activation handoff

print(f"{'stages':>7s} {'org':>8s} {'max_hops':>9s} {'worst_link_B':>13s} "
      f"{'handoff_us':>11s}")
for n_stages in (2, 4, 8):
    for org in (StageOrg.BLOCKED, StageOrg.STRIPED):
        c = placement_cost(org, n_stages, N_DEV, float(BYTES))
        print(f"{n_stages:7d} {org.value:>8s} {c['max_hops']:9d} "
              f"{c['worst_link_bytes']:13.0f} "
              f"{c['handoff_seconds']*1e6:11.3f}")

print("\npermutations (4 stages, 16 devices):")
print("  blocked:", handoff_permutation(StageOrg.BLOCKED, 4, N_DEV)[:6], "...")
print("  striped:", handoff_permutation(StageOrg.STRIPED, 4, N_DEV)[:6], "...")

print("\nplacement choice (Sec. IV-B at pod scale):")
print("  pipelining-dominated ->",
      choose_placement(4, N_DEV, 1e9, 1e6).value)
print("  TP-collective-dominated ->",
      choose_placement(4, N_DEV, 1e6, 1e9).value)

# chip scale: the same organization question, answered end-to-end by the
# Planner facade (spatial org + depth chosen per segment by the DP mapper)
from repro.configs.xrbench import all_tasks
from repro.core import PAPER_HW, PlanRequest, get_planner

plan = get_planner().plan(PlanRequest(all_tasks()["hand_tracking"],
                                      hw=PAPER_HW))
print("\nchip-scale plan (hand_tracking via Planner facade):")
for s in plan.segments[:8]:
    org = s.org.value if s.org is not None else "-"
    print(f"  ops[{s.segment.start:3d}:{s.segment.stop:3d}] depth "
          f"{s.segment.depth}  org {org:16s} "
          f"latency {s.cost.latency_cycles:.3e}")
print(f"  ... {len(plan.segments)} segments, total latency "
      f"{plan.latency_cycles:.3e} cycles")


# branch-aware co-placement: a series-parallel region (e.g. a ResNet
# block's {c1,c2,c3} || {proj} branches) placed side by side on the
# substrate instead of serialized in topological order.  The ASCII map
# shows each PE's owning slot: branches own disjoint regions, and the
# join absorbs every branch tail.
def render_substrate(seg, downsample=2):
    grid = seg.placement.grid[::downsample, ::downsample]
    # one glyph per slot; sized past hw.max_depth (32 on the paper array)
    glyphs = "0123456789abcdefghijklmnopqrstuvwxyzABCDEF"
    branch_of = {s: bi for bi, br in enumerate(seg.branches) for s in br}
    print(f"\n  substrate map ({seg.org.value}"
          f"{', via GB' if seg.placement.via_global_buffer else ''}; "
          f"one char per {downsample}x{downsample} PEs):")
    for row in grid:
        print("    " + "".join(glyphs[s] for s in row))
    for slot, op in enumerate(seg.ops):
        role = (f"branch {branch_of[slot]}" if slot in branch_of
                else ("join" if slot == len(seg.ops) - 1 else "fork"))
        print(f"    {glyphs[slot]} = {op.name:14s} ({role}, "
              f"{seg.pe_alloc[slot]} PEs)")
    print("    pipeline edges:", " ".join(f"{u}->{v}" for u, v in seg.edges))


branchy = get_planner().plan(PlanRequest(all_tasks()["object_detection"],
                                         hw=PAPER_HW))
branch_segs = [s for s in branchy.segments if s.edges]
print(f"\nbranch co-placement (object_detection: "
      f"{len(branch_segs)} branch-parallel segment(s)):")
for seg in branch_segs[:1]:
    names = [op.name for op in seg.ops]
    print(f"  ops[{seg.segment.start}:{seg.segment.stop}] = {names}")
    render_substrate(seg)
