"""PipeOrgan spatial organization at pod scale: blocked vs striped
pipeline-stage placement on the ICI mesh.

    PYTHONPATH=src python examples/pipeline_placement.py
"""
from repro.distributed.pipeline import (StageOrg, choose_placement,
                                        handoff_permutation, placement_cost)

N_DEV = 16          # model-axis devices of one pod row
BYTES = 64 * 2048 * 2   # one microbatch activation handoff

print(f"{'stages':>7s} {'org':>8s} {'max_hops':>9s} {'worst_link_B':>13s} "
      f"{'handoff_us':>11s}")
for n_stages in (2, 4, 8):
    for org in (StageOrg.BLOCKED, StageOrg.STRIPED):
        c = placement_cost(org, n_stages, N_DEV, float(BYTES))
        print(f"{n_stages:7d} {org.value:>8s} {c['max_hops']:9d} "
              f"{c['worst_link_bytes']:13.0f} "
              f"{c['handoff_seconds']*1e6:11.3f}")

print("\npermutations (4 stages, 16 devices):")
print("  blocked:", handoff_permutation(StageOrg.BLOCKED, 4, N_DEV)[:6], "...")
print("  striped:", handoff_permutation(StageOrg.STRIPED, 4, N_DEV)[:6], "...")

print("\nplacement choice (Sec. IV-B at pod scale):")
print("  pipelining-dominated ->",
      choose_placement(4, N_DEV, 1e9, 1e6).value)
print("  TP-collective-dominated ->",
      choose_placement(4, N_DEV, 1e6, 1e9).value)

# chip scale: the same organization question, answered end-to-end by the
# Planner facade (spatial org + depth chosen per segment by the DP mapper)
from repro.configs.xrbench import all_tasks
from repro.core import PAPER_HW, get_planner

plan = get_planner().plan(all_tasks()["hand_tracking"], hw=PAPER_HW)
print("\nchip-scale plan (hand_tracking via Planner facade):")
for s in plan.segments[:8]:
    org = s.org.value if s.org is not None else "-"
    print(f"  ops[{s.segment.start:3d}:{s.segment.stop:3d}] depth "
          f"{s.segment.depth}  org {org:16s} "
          f"latency {s.cost.latency_cycles:.3e}")
print(f"  ... {len(plan.segments)} segments, total latency "
      f"{plan.latency_cycles:.3e} cycles")
