"""Differential-validate an XR-bench plan against the event simulator.

The analytical planner picks every depth/organization from closed-form
interval equations; ``Planner.validate`` re-executes the chosen plan on the
discrete-event simulator (per-link FIFOs over the same routes, GB staging,
fill/drain) and checks the declared error-band contract segment by segment.

    PYTHONPATH=src python examples/validate_plan.py [task]
"""
import sys

from repro.configs.xrbench import all_tasks
from repro.core import (LATENCY_BAND, PAPER_HW, PlanRequest, Topology,
                        get_planner, get_span_shelf, span_cache_info,
                        verify_plan)

task = sys.argv[1] if len(sys.argv) > 1 else "keyword_spotting"
g = all_tasks()[task]

planner = get_planner()
request = PlanRequest(g, hw=PAPER_HW, topology=Topology.AMP)
plan = planner.plan(request)
report = planner.validate(request)   # plans through the same cache entry

print(f"{task}: {len(report.segments)} segments, "
      f"band {LATENCY_BAND[0]}..{LATENCY_BAND[1]} (analytical/simulated)\n")
print(f"{'segment':>10s} {'analytical':>14s} {'simulated':>14s} "
      f"{'ratio':>7s} {'congested(a/s)':>15s}")
for s in report.segments:
    print(f"[{s.start:3d},{s.stop:3d}) {s.analytical_latency:14.0f} "
          f"{s.simulated_latency:14.0f} {s.ratio:7.3f} "
          f"{str(s.analytical_congested):>7s}/{s.simulated_congested!s:<7s}")

print(f"\nwithin band: {report.latency_within_band}   "
      f"verdicts agree: {report.verdicts_agree}   "
      f"ratio span [{report.min_ratio:.3f}, {report.max_ratio:.3f}]")
if not report.ok:
    print("NOTE: marginal congestion verdicts can flip where the analytical "
          "producer-side stall chaining is conservative (docs/simulator.md).")

# the static verifier checks the same plan without touching the simulator:
# placement/routing/granularity/conservation invariants (docs/verifier.md)
print("\nstatic verifier (no simulator):")
print(verify_plan(plan, hw=PAPER_HW, topology=Topology.AMP).summary())

print("\ncache statistics (hits/misses/size) after plan + validate:")
# registry entries may be empty (never hit) or unbounded (maxsize=None,
# e.g. the jax jitted-callable cache) — print them all without assuming
# every field is a populated int
for name, ci in planner.cache_info_all().items():
    hits = ci.hits or 0
    misses = ci.misses or 0
    size = "-" if ci.currsize is None else str(ci.currsize)
    cap = "unbounded" if ci.maxsize is None else str(ci.maxsize)
    print(f"  {name:>12s}: {hits:6d} hits  {misses:6d} misses  "
          f"{size:>5s}/{cap} entries")

# the DP span cache is two-tier: an in-memory LRU backed by an optional
# on-disk SpanShelf (install one with Planner(span_shelf=...) — see
# docs/planner.md); report both tiers explicitly
mem_hits, mem_misses, _, mem_size = span_cache_info()
print(f"\nspan tiers: memory {mem_hits} hits / {mem_misses} misses "
      f"({mem_size} spans resident)")
shelf = get_span_shelf()
if shelf is None:
    print("            shelf  not installed (cold planning solves every "
          "unique span)")
else:
    s_hits, s_misses, _, s_size = shelf.info()
    print(f"            shelf  {s_hits} hits / {s_misses} misses "
          f"({s_size} spans at {shelf.root})")
