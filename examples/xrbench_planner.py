"""Sweep all XR-bench tasks across topologies — the paper's design-time
traffic analysis (Figs. 8-12) driven end to end through the ``Planner``
facade (plans are LRU-cached, so re-running a task/topology is free).

    PYTHONPATH=src python examples/xrbench_planner.py
"""
from repro.configs.xrbench import all_tasks
from repro.core import PAPER_HW, PlanRequest, Topology, get_planner

planner = get_planner()

print(f"{'task':22s} {'mesh':>12s} {'AMP':>12s} {'torus':>12s} "
      f"{'fbfly':>12s}")
for name, g in all_tasks().items():
    row = [name]
    for topo in (Topology.MESH, Topology.AMP, Topology.TORUS,
                 Topology.FLATTENED_BUTTERFLY):
        plan = planner.plan(PlanRequest(g, hw=PAPER_HW, topology=topo))
        row.append(f"{plan.latency_cycles:.3e}")
    print(f"{row[0]:22s} {row[1]:>12s} {row[2]:>12s} {row[3]:>12s} "
          f"{row[4]:>12s}")
print("\nlatency cycles per inference; lower is better.  AMP recovers "
      "most of flattened-butterfly's benefit at <2x mesh wiring.")
print(f"plan cache: {planner.cache_info()}")
