"""Quickstart: plan a model with PipeOrgan and inspect the decisions.

Planning is a *query*: a ``PlanRequest`` names the workload, hardware,
topology and an ``Objective`` over (latency, DRAM, energy); the planner
answers from its cut-point DP's Pareto frontier.  The default objective
is latency-first — swap in ``min_dram()`` (or a ``Constraint``) and the
same frontier yields a different plan.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.xrbench import eye_segmentation
from repro.core import (PAPER_HW, PlanRequest, Topology, get_planner,
                        min_dram, plan_tangram_like)

graph = eye_segmentation()          # RITNet-style DAG (77 ops, dense skips)
print(f"model: {graph.name} | ops={len(graph.ops)} "
      f"skips={len(graph.skip_edges())}")

planner = get_planner()
plan = planner.plan(PlanRequest(graph, hw=PAPER_HW, topology=Topology.AMP))
print(f"\nPipeOrgan plan ({len(plan.segments)} segments):")
for seg in plan.segments[:8]:
    names = [o.name for o in seg.ops]
    print(f"  depth={seg.segment.depth:2d} org={seg.org and seg.org.value} "
          f"lat={seg.cost.latency_cycles:9.3e}cy "
          f"ops={names[0]}..{names[-1]}")
print("  ...")

baseline = plan_tangram_like(graph, PAPER_HW)
print(f"\nlatency:  pipeorgan={plan.latency_cycles:.3e} cycles | "
      f"tangram-like={baseline.latency_cycles:.3e}  "
      f"(speedup {baseline.latency_cycles / plan.latency_cycles:.2f}x)")
print(f"DRAM:     pipeorgan={plan.dram_bytes:.3e} B | "
      f"tangram-like={baseline.dram_bytes:.3e}  "
      f"(ratio {plan.dram_bytes / baseline.dram_bytes:.2f})")

# the same frontier, a different objective: minimize DRAM traffic
frugal = planner.plan(PlanRequest(graph, hw=PAPER_HW, topology=Topology.AMP,
                                  objective=min_dram()))
print(f"\nmin-DRAM objective: {frugal.dram_bytes:.3e} B "
      f"({frugal.dram_bytes / plan.dram_bytes:.2f}x of latency-first) at "
      f"{frugal.latency_cycles:.3e} cycles")
